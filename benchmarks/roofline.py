"""Roofline table (EXPERIMENTS.md §Roofline) from the dry-run artifacts.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun),
prints the per-(arch x shape x mesh) three-term roofline and writes the
markdown table + the LM-service calibration file used by the autoscaling
demo (closing the loop: the surfaces RASK optimizes come from compiled HLO).
"""
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"
DRY = ART / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def rows():
    out = []
    for p in sorted(DRY.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def kernel_floor_s(r) -> float:
    """Decode cells: the Pallas decode kernel streams weights + KV cache
    exactly once in bf16 (by construction of its BlockSpec grid), so its
    memory floor is arg_bytes / HBM_BW. The XLA reference path measured in
    memory_s round-trips the cache ~3x (f32-emulated dots + layout
    transposes on the CPU lowering)."""
    return r["arg_bytes_per_device"] / HBM_BW


def markdown_table(data):
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | kernel_s | "
        "collective_s | bottleneck | MODEL_FLOPS | useful | roofline_frac | "
        "kernel_frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in data:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        n_dev = 512 if "pods" in r["mesh"] else 256
        ideal = r["model_flops"] / (n_dev * PEAK_FLOPS)
        frac = ideal / dom if dom > 0 else 0.0
        is_serve = r["shape"] in ("decode_32k", "long_500k")
        kf = kernel_floor_s(r) if is_serve else float("nan")
        kdom = max(r["compute_s"], kf, r["collective_s"]) if is_serve else dom
        kfrac = ideal / kdom if kdom > 0 else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {kf:.3e} | {r['collective_s']:.3e} | {r['bottleneck']} "
            f"| {r['model_flops']:.2e} | {r['useful_flops_frac']:.3f} "
            f"| {frac:.4f} | {kfrac:.4f} |")
    return "\n".join(lines)


def lm_calibration(data):
    """tokens/s/chip per arch from the decode_32k single-pod roofline
    (kernel floor — the deployable path uses the Pallas decode kernel)."""
    cal = {}
    for r in data:
        if r["shape"] != "decode_32k" or r["mesh"] != "pod16x16":
            continue
        dom = max(r["compute_s"], kernel_floor_s(r), r["collective_s"])
        if dom <= 0:
            continue
        # decode_32k: 128 sequences produce 1 token per step
        tokens_per_s_per_chip = 128 / (dom * 256)
        # rung scaling mirrors profiles._RUNG_FRACTION (N_eff linear in rung)
        cal[r["arch"]] = {str(rung): tokens_per_s_per_chip * 4.0 / rung
                          for rung in (1, 2, 3, 4)}
    return cal


def rask_objective_rows(s_list=(3, 9, 27), k_starts=8):
    """Three-term roofline for the RASK batched-objective kernel
    (kernels/rask_objective.py) at the e7 problem shapes.

    Paper layout per 3 services: 7 decision params, 3 relations (F_max = 3,
    degree 2 -> T = 10 terms), 7 SLOs.  Counts assume the kernel's one-hot
    matmul formulation: feature gather, parameter/relation picks and the
    per-service segment-sum are all dense matmuls; term products come from
    statically-unrolled powers.  The kernel is microscopically small for a
    TPU — both floors land in the tens of nanoseconds, i.e. the op is
    dispatch-bound, which is exactly why the solver batches K starts (and a
    Fleet batches hosts) into ONE launch rather than looping.
    """
    out = []
    for s in s_list:
        units = s // 3
        D, R, Q, T, F, deg = 7 * units, 3 * units, 7 * units, 10, 3, 2
        flops = k_starts * (2 * R * F * D            # one-hot gather matmul
                            + R * T * F * (deg + 2)  # power select + product
                            + 2 * R * T              # weighted term sum
                            + 2 * Q * (D + R + 4)    # picks + phi
                            + 2 * Q * s)             # segment-sum matmul
        floats = (k_starts * D + R * F * D + Q * D + Q * R + Q * s
                  + R * T * F + 2 * R * T + R * F + 4 * Q + s
                  + k_starts * s)
        bytes_ = 4 * floats
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_ / HBM_BW
        out.append(dict(S=s, K=k_starts, flops=flops, bytes=bytes_,
                        compute_s=compute_s, memory_s=memory_s,
                        bound="memory" if memory_s > compute_s else "compute",
                        intensity=flops / bytes_))
    return out


def dispatch_floor_rows(s_list=(3, 9), reps=100):
    """Empirical host dispatch floor of the fused decide program (ISSUE 8).

    The decide op is dispatch-bound (see ``rask_objective_rows``): its
    device floors are tens of nanoseconds, so per-cycle latency is set by
    how fast the host can launch it.  This measures the SAME compiled
    program invoked two ways, at real agent shapes:

    * jit — through the ``jax.jit`` python dispatcher (argument flatten,
      signature hash, cache lookup, guard logic on every call);
    * aot — ``jax.jit(f).lower(...).compile()`` once, then the compiled
      executable called directly (what ``RaskConfig.aot`` ships and
      ``RASKAgent.precompile`` warms).

    Measured result (recorded in roofline_dispatch.json): on CPU jax the
    WARM dispatch floor slightly favors the jit C++ fastpath (~10us) over
    the direct ``Compiled.call`` python entry (~18us) — the AOT win is the
    COLD start: ``warm_ms`` of trace+compile leaves the control loop
    entirely (``precompile`` pays it from ShapeDtypeStructs before the
    first cycle), so no decide ever stalls on a compile.  Zero-filled
    inputs: the ridge term keeps the zero-Gram solve well-posed, and
    dispatch cost is shape-dependent only."""
    import time

    import jax
    import jax.numpy as jnp

    from benchmarks import common
    from repro.core.rask import _AotFn

    out = []
    for s_count in s_list:
        env = common.make_env(seed=0, replicas=max(s_count // 3, 1),
                              capacity=8.0 * max(s_count // 3, 1))
        agent = common.make_rask(env, 0)
        cap = 64
        key = (cap, agent._static_degrees())
        agent._fit_plan = agent._make_plan(cap, key[1])
        agent._fit_plan_key = key
        k_cap = (agent._fit_plan.delta_capacity(0)
                 if agent._streaming() else None)
        fn = agent._build_fused_fn(k_cap)
        if not isinstance(fn, _AotFn):      # aot disabled in this config
            continue
        avals = agent._decide_avals(k_cap)
        zeros = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), avals)
        jit_us = common.bench(
            lambda: jax.block_until_ready(fn._jit(*zeros)), reps)
        t0 = time.perf_counter()
        fn.warm(*avals)
        warm_ms = (time.perf_counter() - t0) * 1e3
        aot_us = common.bench(
            lambda: jax.block_until_ready(fn(*zeros)), reps)
        # pure dispatch floor: a no-op program over the SAME argument tree
        # (decide compute hides the delta in noise at small S; this isolates
        # the host-side flatten/hash/lookup cost itself)
        floor = _AotFn(lambda *a: jax.tree_util.tree_leaves(a)[-1])
        floor_jit_us = common.bench(
            lambda: jax.block_until_ready(floor._jit(*zeros)), reps)
        floor.warm(*avals)
        floor_aot_us = common.bench(
            lambda: jax.block_until_ready(floor(*zeros)), reps)
        out.append(dict(S=s_count, jit_us=jit_us, aot_us=aot_us,
                        saved_us=jit_us - aot_us,
                        saved_frac=(jit_us - aot_us) / jit_us,
                        warm_ms=warm_ms,
                        floor_jit_us=floor_jit_us,
                        floor_aot_us=floor_aot_us))
    return out


def measured_serving_row():
    """The e11 MEASURED stacked-engine point (tokens/s on the smoke model),
    printed next to the analytic floors: the only row in this table that
    comes from wall-clock decode steps rather than a cost model."""
    p = ART / "e11_serving.json"
    if not p.exists():
        return None
    return json.loads(p.read_text()).get("roofline_point")


def main():
    measured = measured_serving_row()
    if measured:
        print(f"roofline[measured,{measured['arch']}-smoke,"
              f"slots={measured['slots']}],{measured['step_us']:.0f},"
              f"{measured['tokens_per_s']:.0f}tok/s MEASURED"
              f" (e11 stacked engine)")
    dispatch = dispatch_floor_rows()
    for r in dispatch:
        print(f"roofline[dispatch,S={r['S']}],{r['aot_us']:.0f},"
              f"jit={r['jit_us']:.0f}us saved={r['saved_us']:.0f}us"
              f" ({100 * r['saved_frac']:.0f}%)"
              f" cold-compile={r['warm_ms']:.0f}ms"
              f" floor jit={r['floor_jit_us']:.0f}us"
              f" aot={r['floor_aot_us']:.0f}us")
    if dispatch:
        (ART / "roofline_dispatch.json").write_text(
            json.dumps(dispatch, indent=1))
    for r in rask_objective_rows():
        dom = max(r["compute_s"], r["memory_s"])
        print(f"roofline[rask_objective,S={r['S']},K={r['K']}],"
              f"{dom * 1e6:.3f},{r['bound']}-bound"
              f" intensity={r['intensity']:.2f}flop/B")
    data = rows()
    if not data:
        print("roofline,0,no-dryrun-artifacts")
        return
    table = markdown_table(data)
    (ART / "roofline_table.md").write_text(table)
    cal = lm_calibration(data)
    (ART / "lm_calibration.json").write_text(json.dumps(cal, indent=1))
    for r in data:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline[{r['arch']},{r['shape']},{r['mesh']}],"
              f"{dom * 1e6:.1f},{r['bottleneck']}")


if __name__ == "__main__":
    main()
