"""E2 (Table IV): polynomial degree vs test-split MSE per service.

Training data comes from an E1-style run ({xi=20, eta=0}); each service's
(X, Y) table is fit at degrees 1..6 and scored on a 20% split.
"""
import numpy as np

from repro.core.regression import fit_polynomial, mse, train_test_split

from . import common


def run(duration: float = common.E1_DURATION, seed: int = 0):
    env = common.make_env(seed=seed)
    agent = common.make_rask(env, seed=seed, xi=20, eta=0.0)
    common.run_agent(env, agent, duration)

    table = {}
    best = {}
    for sid in agent.services:
        svc = env.platform.service(sid)
        feats = tuple(agent.knowledge[svc.sid.type]["tp_max"])
        X, Y = agent.table.design_matrix(sid, feats, "tp_max")
        scale = [svc.api.parameter(f).max_value for f in feats]
        Xtr, Ytr, Xte, Yte = train_test_split(X, Y, seed=seed)
        row = {}
        for d in range(1, 7):
            m = fit_polynomial(Xtr, Ytr, d, x_scale=scale)
            row[d] = float(mse(m, Xte, Yte))
        table[svc.sid.type] = row
        best[svc.sid.type] = min(row, key=row.get)
    out = {"mse": table, "best_degree": best}
    common.save("e2_poly_degree", out)
    return out


def main():
    r = run()
    for svc, row in r["mse"].items():
        print(f"e2[{svc}],0,best_degree={r['best_degree'][svc]}")


if __name__ == "__main__":
    main()
