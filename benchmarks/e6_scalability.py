"""E6 (Fig. 11): scalability to 3/6/9 services (replicated QR/CV/PC images,
proportional capacity 8/16/24 cores). Also the beyond-paper comparison:
the vmapped multi-start PGD solver vs scipy SLSQP at each |S| — the paper's
Discussion explicitly flags solver parallelization as the fix for E6's
runtime growth.
"""
import numpy as np

from . import common


def run(reps: int = common.REPS, duration: float = common.E3_DURATION / 2,
        backends=("slsqp", "pgd"), fleet: bool = False):
    """``fleet=True`` spreads the replicated services over one 8-core device
    each (a Fleet of |replicas| hosts) instead of one big device — same |S|
    growth, per-device constraints arbitrated by the plan control plane."""
    results = {}
    for backend in backends:
        for replicas, cores in ((1, 8.0), (2, 16.0), (3, 24.0)):
            runs = []
            for rep in range(reps):
                patterns = common.e3_patterns("diurnal", duration, seed=rep)
                env = common.make_env(seed=rep, patterns=patterns,
                                      replicas=replicas,
                                      capacity=8.0 if fleet else cores,
                                      hosts=replicas if fleet else 1)
                agent = common.make_rask(env, seed=rep, xi=20, eta=0.0,
                                         backend=backend)
                runs.append(common.run_agent(env, agent, duration))
            rts = np.concatenate([r["runtime_ms"] for r in runs])
            fls = np.concatenate([r["fulfillment"] for r in runs])
            results[f"{backend},S={replicas * 3}"] = {
                "median_runtime_ms": float(np.median(rts)),
                "runtime_ms_p95": float(np.percentile(rts, 95)),
                "max_runtime_ms": float(np.max(rts)),
                "median_fulfillment": float(np.median(fls)),
            }
    common.save("e6_scalability_fleet" if fleet else "e6_scalability", results)
    return results


def main():
    r = run()
    for k, v in r.items():
        print(f"e6[{k}],{v['median_runtime_ms'] * 1e3:.0f},"
              f"{v['median_fulfillment']:.4f}")


if __name__ == "__main__":
    main()
