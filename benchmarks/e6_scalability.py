"""E6 (Fig. 11): scalability to 3/6/9 services (replicated QR/CV/PC images,
proportional capacity 8/16/24 cores). Also the beyond-paper comparison:
the vmapped multi-start PGD solver vs scipy SLSQP at each |S| — the paper's
Discussion explicitly flags solver parallelization as the fix for E6's
runtime growth.

``--hetero`` (beyond-paper) exercises the *heterogeneous* fleet engine:

* a seeded two-tier scenario (10 services capacity-placed 2/8 over a
  4-core and a 16-core device, mixed diurnal/bursty/constant load) driven
  by RASK end-to-end, with a steady-state recompile guard;
* a solve microbench on a 2-bucket fleet — hosts of 2 and of 8 services —
  comparing the bucketed per-host dispatch against the single padded
  layout (every host padded to the largest) and the sequential per-host
  loop, plus the bucketed-vs-sequential parity gap (acceptance: <= 1e-5).

Bucketing trades one extra compiled scan per layout bucket for not padding
small hosts to the largest host's layout, so it pays off once buckets hold
several hosts each (the XLA-CPU dispatch floor dominates below that) —
``SOLVE_FLEET`` sizes the committed artifact past that crossover.

The ISSUE-7 control-plane scale suite rides the same artifact:

* ``scale`` — the bucketed fleet solve swept to the 1000-service /
  100-host point (``SCALE_FLEETS``), with the least-squares scaling
  exponent of solve time in |S| (acceptance: <= 1.2 — the vmapped
  one-dispatch path must stay near-linear), the wall time of the largest
  point (acceptance: < 10 s, i.e. inside one control interval), and the
  sharded-vs-unsharded byte parity at that point (``shard="auto"`` via
  ``shard_map`` when multiple XLA devices exist; acceptance: exactly 0.0);
* ``pipeline`` — decide latency with ``RaskConfig(pipeline=True)`` vs the
  synchronous path on a seeded 48-service / 16-host fleet driven
  end-to-end: the dispatch-then-collect cycle must hide >= 50% of the
  solve latency behind the apply + telemetry-scrape window.

``benchmarks/run.py --check e6`` re-runs the microbenches against the
committed artifact and fails on a solve-time regression, a parity gap, a
lost speedup, a superlinear scaling exponent, a blown control interval at
the 1000-service point, a pipeline that stops hiding its solve, or any
steady-state recompile.
"""
import numpy as np

from . import common

# the 2-bucket acceptance fleet: (n_hosts, services_per_host, cores_per_host)
SOLVE_FLEET = ((16, 2, 4.0), (8, 8, 16.0))
SOLVE_REPS = 7
SCENARIO_REPS = 2
SCENARIO_DURATION = None     # None -> E3_DURATION / 2 at call time
HETERO_ARTIFACT = "e6_hetero"

# ISSUE-7 scale sweep: same-shape fleets (10 services per host) growing to
# the 1000-service / 100-host acceptance point, so the fitted exponent
# measures |S| growth and not layout-bucket churn
SCALE_FLEETS = ((13, 10, 20.0), (25, 10, 20.0), (50, 10, 20.0),
                (100, 10, 20.0))
SCALE_REPS = 3
SCALE_EXPONENT_LIMIT = 1.2
SCALE_INTERVAL_S = 10.0      # one control interval: ceiling for the 1000-pt
PIPELINE_REPLICAS = 16       # 16 x paper triple = 48 services on 16 hosts
PIPELINE_HOSTS = 16
PIPELINE_DURATION = 500.0
PIPELINE_HIDDEN_MIN = 0.5


def run(reps: int = common.REPS, duration: float = common.E3_DURATION / 2,
        backends=("slsqp", "pgd"), fleet: bool = False):
    """``fleet=True`` spreads the replicated services over one 8-core device
    each (a Fleet of |replicas| hosts) instead of one big device — same |S|
    growth, per-device constraints arbitrated by the plan control plane."""
    results = {}
    for backend in backends:
        for replicas, cores in ((1, 8.0), (2, 16.0), (3, 24.0)):
            runs = []
            for rep in range(reps):
                patterns = common.e3_patterns("diurnal", duration, seed=rep)
                env = common.make_env(seed=rep, patterns=patterns,
                                      replicas=replicas,
                                      capacity=8.0 if fleet else cores,
                                      hosts=replicas if fleet else 1)
                agent = common.make_rask(env, seed=rep, xi=20, eta=0.0,
                                         backend=backend)
                runs.append(common.run_agent(env, agent, duration))
            rts = np.concatenate([r["runtime_ms"] for r in runs])
            fls = np.concatenate([r["fulfillment"] for r in runs])
            results[f"{backend},S={replicas * 3}"] = {
                "median_runtime_ms": float(np.median(rts)),
                "runtime_ms_p95": float(np.percentile(rts, 95)),
                "max_runtime_ms": float(np.max(rts)),
                "median_fulfillment": float(np.median(fls)),
            }
    common.save("e6_scalability_fleet" if fleet else "e6_scalability", results)
    return results


def _solve_fleet(fleet=SOLVE_FLEET):
    """Synthetic fleet problem (``fleet`` tiers of (n_hosts, services_per_
    host, cores_per_host)) with fitted paper-like 3-parameter services —
    returns (problem, host_of, caps, models, rps, x0)."""
    from repro.core.regression import fit_polynomial
    from repro.core.slo import SLO
    from repro.core.solver import ServiceSpec, SolverProblem

    specs, host_of, caps = [], {}, {}
    for tier, (n_hosts, n_svc, cores) in enumerate(fleet):
        for h in range(n_hosts):
            hostname = f"tier{tier}-{h}"
            caps[hostname] = cores
            for i in range(n_svc):
                s = ServiceSpec(
                    name=f"t{tier}h{h}s{i}",
                    param_names=("cores", "data_quality", "model_size"),
                    lower=(0.1, 100.0, 1.0), upper=(8.0, 1000.0, 4.0),
                    resource_mask=(True, False, False),
                    slos=(SLO("data_quality", 800.0, 0.5),
                          SLO("model_size", 3.0, 0.2),
                          SLO("completion", 1.0, 1.0)),
                    relation_features=(("tp_max", (0, 1, 2)),))
                specs.append(s)
                host_of[s.name] = hostname
    problem = SolverProblem(specs)
    rng = np.random.default_rng(0)
    X = np.c_[rng.uniform(0.1, 8, 300), rng.uniform(100, 1000, 300),
              rng.uniform(1, 4, 300)]
    Y = 20 * X[:, 0] - X[:, 1] / 100.0 + 3 * X[:, 2]
    m = fit_polynomial(X.astype(np.float32), Y.astype(np.float32), 2,
                       x_scale=[8.0, 1000.0, 4.0])
    models = {s.name: {"tp_max": m} for s in specs}
    rps = np.full(len(specs), 50.0, np.float32)
    x0 = problem.random_assignment(np.random.default_rng(1),
                                   float(sum(caps.values())))
    return problem, host_of, caps, models, rps, x0


def solve_bench(reps: int = None) -> dict:
    """Bucketed vs single-padded-layout vs sequential per-host solves on
    the 2-bucket SOLVE_FLEET, plus the bucketed/sequential parity gap."""
    from repro.core.solver import FleetSolverProblem

    reps = SOLVE_REPS if reps is None else reps

    problem, host_of, caps, models, rps, x0 = _solve_fleet()
    fb = FleetSolverProblem(problem, host_of, caps)
    fu = FleetSolverProblem(problem, host_of, caps, bucketed=False)
    a_b, _ = fb.solve_many(models, rps, x0)
    a_q, _ = fb.solve_sequential(models, rps, x0)
    row = {
        "hosts": "+".join(f"{n}x{s}svc" for n, s, _ in SOLVE_FLEET),
        "services": len(problem.specs),
        "buckets": [list(bk.key) for bk in fb.buckets],
        "bucketed_us": common.bench(
            lambda: fb.solve_many(models, rps, x0), reps),
        "padded_us": common.bench(
            lambda: fu.solve_many(models, rps, x0), reps),
        "sequential_us": common.bench(
            lambda: fb.solve_sequential(models, rps, x0), max(reps // 2, 2)),
        "parity_max_abs_diff": float(np.max(np.abs(a_b - a_q))),
    }
    row["bucketed_speedup"] = row["padded_us"] / row["bucketed_us"]
    row["sequential_speedup"] = row["sequential_us"] / row["bucketed_us"]
    return row


def scale_bench(reps: int = None, fleets=None) -> dict:
    """The control plane at 1000 services: bucketed solve time swept over
    ``SCALE_FLEETS``, the fitted |S| scaling exponent, the largest point's
    wall time against one control interval, and sharded-vs-unsharded byte
    parity at that point (real multi-device parity when run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    import jax

    from repro.core.solver import FleetSolverProblem

    reps = SCALE_REPS if reps is None else reps
    fleets = SCALE_FLEETS if fleets is None else fleets
    points = []
    fp = None
    for fleet in fleets:
        problem, host_of, caps, models, rps, x0 = _solve_fleet((fleet,))
        fp = FleetSolverProblem(problem, host_of, caps, shard="auto")
        t_us = common.bench(lambda: fp.solve_many(models, rps, x0),
                            reps, warmup=1)
        points.append({"services": len(problem.specs), "hosts": len(caps),
                       "solve_us": t_us})
    xs = np.log([p["services"] for p in points])
    ys = np.log([p["solve_us"] for p in points])
    exponent = float(np.polyfit(xs, ys, 1)[0])
    # byte parity at the largest point: sharding changes WHERE a host's
    # subproblem runs, never what it computes
    a_s, s_s = fp.solve_many(models, rps, x0)
    f0 = FleetSolverProblem(problem, host_of, caps, shard=False)
    a_0, s_0 = f0.solve_many(models, rps, x0)
    parity = float(max(np.max(np.abs(a_s - a_0)), np.max(np.abs(s_s - s_0))))
    return {"points": points,
            "scaling_exponent": exponent,
            "largest_solve_s": points[-1]["solve_us"] / 1e6,
            "n_devices": jax.device_count(),
            "n_shards": fp.n_shards,
            "shard_parity_max_abs_diff": parity}


def pipeline_bench(duration: float = None, seed: int = 0) -> dict:
    """Pipelined vs synchronous decide on a seeded 48-service / 16-host
    fleet driven end-to-end: ``runtime_s`` of a pipelined cycle is only the
    blocked dispatch + collect time — the solve itself runs on device while
    the plan is applied and telemetry scraped.  Reports the hidden fraction
    of the synchronous solve latency (acceptance: >= PIPELINE_HIDDEN_MIN)
    and the fulfillment cost of the one-cycle plan lag."""
    from repro.core import RASKAgent, RaskConfig
    from repro.env import EdgeEnvironment, paper_knowledge, paper_profiles

    duration = PIPELINE_DURATION if duration is None else duration

    def drive(pipeline: bool):
        env = EdgeEnvironment(list(paper_profiles().values()),
                              {"cores": 8.0}, replicas=PIPELINE_REPLICAS,
                              hosts=PIPELINE_HOSTS, seed=seed)
        agent = RASKAgent(env.platform, paper_knowledge(),
                          RaskConfig(xi=14, eta=0.0, pipeline=pipeline),
                          seed=seed)
        hist = env.run(agent, duration_s=duration)
        solved = [h for h in hist if not h.explored and h.runtime_s > 0]
        return {
            "median_runtime_ms": float(np.median(
                [h.runtime_s for h in solved]) * 1e3),
            "median_dispatch_ms": float(np.median(
                [h.dispatch_s for h in solved]) * 1e3),
            "median_collect_ms": float(np.median(
                [h.collect_s for h in solved]) * 1e3),
            "mean_fulfillment": float(np.mean(
                [h.fulfillment for h in hist[agent.cfg.xi:]])),
        }

    sync, piped = drive(False), drive(True)
    hidden = 1.0 - piped["median_runtime_ms"] / sync["median_runtime_ms"]
    return {"services": PIPELINE_REPLICAS * 3, "hosts": PIPELINE_HOSTS,
            "sync": sync, "pipelined": piped,
            "hidden_fraction": float(hidden)}


def scenario_bench(reps: int = None, duration: float = None) -> dict:
    """The seeded two-tier RASK run: fulfillment + decide runtime + a
    steady-state recompile guard over extra post-run decides."""
    from repro.core import RASKAgent, RaskConfig
    from repro.core.regression import TRACE_COUNTS
    from repro.env import two_tier_environment

    reps = SCENARIO_REPS if reps is None else reps
    if duration is None:
        duration = SCENARIO_DURATION if SCENARIO_DURATION is not None \
            else common.E3_DURATION / 2
    runs, recompiles = [], 0
    for rep in range(reps):
        env, knowledge = two_tier_environment(duration_s=duration, seed=rep)
        agent = RASKAgent(env.platform, knowledge,
                          RaskConfig(xi=20, eta=0.0), seed=rep)
        runs.append(common.run_agent(env, agent, duration))
        traces0 = dict(TRACE_COUNTS)
        for _ in range(3):            # steady state: decides must not retrace
            agent.decide(agent.observe(env.t))
        # h2d_delta_rows is a runtime transfer counter that legitimately
        # moves every streaming cycle; traces AND design-window uploads
        # must both stay flat
        recompiles += sum(TRACE_COUNTS[k] - traces0.get(k, 0)
                          for k in TRACE_COUNTS if k != "h2d_delta_rows")
    rts = np.concatenate([r["runtime_ms"] for r in runs])
    fls = np.concatenate([r["fulfillment"] for r in runs])
    return {
        "services": 10, "hosts": "1x4core(2svc)+1x16core(8svc)",
        "median_runtime_ms": float(np.median(rts)),
        "median_fulfillment": float(np.median(fls)),
        "mean_fulfillment": float(np.mean(fls)),
        "steady_state_recompiles": int(recompiles),
    }


def run_hetero(reps: int = None, duration: float = None,
               solve_reps: int = None, stages=None) -> dict:
    """``stages``: subset of ("scenario", "solve", "scale", "pipeline") to
    measure (None = all)."""
    has = (lambda s: True) if stages is None else (lambda s: s in stages)
    results = {}
    if has("scenario"):
        results["scenario"] = scenario_bench(reps, duration)
    if has("solve"):
        results["solve"] = solve_bench(solve_reps)
    if has("scale"):
        results["scale"] = scale_bench()
    if has("pipeline"):
        results["pipeline"] = pipeline_bench()
    common.save(HETERO_ARTIFACT, results)
    return results


def report_hetero(r: dict) -> None:
    s, v = r.get("scenario"), r.get("solve")
    if s:
        print(f"e6[hetero-scenario],{s['median_runtime_ms'] * 1e3:.0f},"
              f"{s['median_fulfillment']:.4f}"
              f" recompiles={s['steady_state_recompiles']}")
    if v:
        print(f"e6[hetero-solve,{v['hosts']}],{v['bucketed_us']:.0f},"
              f"padded={v['padded_us']:.0f}us"
              f" speedup={v['bucketed_speedup']:.2f}x"
              f" seq={v['sequential_us']:.0f}us"
              f" parity={v['parity_max_abs_diff']:.2e}")
    sc = r.get("scale")
    if sc:
        big = sc["points"][-1]
        print(f"e6[scale,S={big['services']}/H={big['hosts']}],"
              f"{big['solve_us']:.0f},exponent={sc['scaling_exponent']:.3f}"
              f" largest={sc['largest_solve_s']:.2f}s"
              f" shards={sc['n_shards']}/{sc['n_devices']}dev"
              f" parity={sc['shard_parity_max_abs_diff']:.2e}")
    p = r.get("pipeline")
    if p:
        print(f"e6[pipeline,S={p['services']}/H={p['hosts']}],"
              f"{p['pipelined']['median_runtime_ms'] * 1e3:.0f},"
              f"sync={p['sync']['median_runtime_ms'] * 1e3:.0f}us"
              f" hidden={p['hidden_fraction']:.1%}"
              f" lag_cost="
              f"{p['sync']['mean_fulfillment'] - p['pipelined']['mean_fulfillment']:+.4f}")


def main_hetero():
    report_hetero(run_hetero())


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hetero", action="store_true",
                    help="run the heterogeneous-fleet suite instead of the "
                         "paper's homogeneous scalability sweep")
    args = ap.parse_args(argv)
    if args.hetero:
        main_hetero()
        return
    r = run()
    for k, v in r.items():
        print(f"e6[{k}],{v['median_runtime_ms'] * 1e3:.0f},"
              f"{v['median_fulfillment']:.4f}")


if __name__ == "__main__":
    main()
