"""E7 (beyond-paper): per-stage cycle hot-path latency vs |S|.

The paper's E6 blames per-cycle agent runtime — "poor parallelization of the
numerical solver" — for the ceiling on services per device.  This benchmark
instruments every stage of the fused batched cycle engine at |S| in
{3, 9, 27} (replicated QR/CV/PC on one device, proportional capacity):

* ``telemetry`` — one full scrape (all containers, bulk ring write) and one
  bulk ``window_states`` aggregation;
* ``tick``      — one vectorized ``ContainerPool.tick`` of the whole fleet;
* ``fit``       — the batched stacked ridge fit vs the seed's per-relation
  ``fit_polynomial`` loop;
* ``solve``     — the backend comparison on identical warm-started problems:
  the default single-dispatch PGD (``solve_us``), PGD scoring through the
  Pallas objective kernel in interpret mode (``solve_pallas_us``), the
  host-looped scipy SLSQP reference (``solve_slsqp_us``, the pre-PR-3
  default: one dispatch + one device sync per line-search iteration), and
  the seed's loop objective (``solve_loop_us``);
* ``solve_many``— a 3-host Fleet decided in ONE vmapped dispatch with
  per-host capacities vs the same subproblems solved sequentially;
* ``decide``    — the full RASK decision as a single fused dispatch
  (fit+solve+project+noise on device) vs the pre-PR-3 SLSQP default
  (``decide_slsqp_us``) and the seed loop path (``decide_loop_us``).

The ``fit_phase`` sweep (ISSUE 8) breaks the fit stage into its transfer
phases — ``pack`` (host buffer fill), ``upload`` (host->device put) and
``update`` (the compiled device work) — for the pre-PR batch path (full
design-window rebuild + upload every cycle) against the streaming
device-resident Gram engine (rank-1 delta push), up to |S|=96.  Synthetic
paper-shaped relations, no agent training: the fit phase depends only on
the plan geometry and the window size.

All timings are steady-state (post jit warm-up) medians.  The artifact also
records jit trace counts over the timed window — zero recompiles after the
first cycle at fixed padding is an acceptance gate of the fused engine, and
(ISSUE 8) so is zero steady-state design-window uploads.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import regression
from repro.core.regression import BatchedFitPlan, TRACE_COUNTS, pad_capacity
from repro.core.solver import SolverProblem

from . import common

S_LIST = (3, 9, 27)
REPS = 20            # reps for cheap stages (telemetry / tick / fit)
SOLVE_REPS = 5       # reps for solve / decide (solver-bound)
TRAIN_CYCLES = 30    # exploration cycles populating the training table
# quick/CI runs save under a different name so the committed full-sweep
# acceptance artifact is never clobbered by |S|=3 smoke data
ARTIFACT = "e7_hot_path"


_bench = common.bench     # shared steady-state timing helper


def _trained_agent(replicas: int, seed: int = 0, hosts: int = 1, **cfg_kw):
    """Environment + RASK agent with a populated training table, one solve
    cycle already done (jit warm)."""
    env = common.make_env(seed=seed, replicas=replicas,
                          capacity=8.0 * (replicas if hosts == 1 else 1),
                          hosts=hosts)
    agent = common.make_rask(env, seed=seed, xi=TRAIN_CYCLES, eta=0.0,
                             **cfg_kw)
    # TRAIN_CYCLES exploration cycles + 2 solve cycles (compile + steady)
    env.run(agent, duration_s=(TRAIN_CYCLES + 2) * common.CYCLE_S)
    return env, agent


def _fleet_sequential(agent):
    """The Python-loop counterpart of ``FleetSolverProblem.solve_many``:
    per-host ``SolverProblem``s solved one after another (models pre-stacked
    outside the timed region — the loop pays only its solves)."""
    fp = agent.fleet_problem
    problem = agent.problem
    models = agent.problem.models_dict(agent.stacked)
    subs = []
    for b, host in enumerate(fp.hosts):
        idx = [i for i, s in enumerate(problem.specs)
               if agent.platform.host_of(s.name).host == host]
        sub = SolverProblem([problem.specs[i] for i in idx])
        sub_sm = sub.stack({problem.specs[i].name:
                            models[problem.specs[i].name] for i in idx})
        take = np.concatenate(
            [np.arange(problem.offsets[i],
                       problem.offsets[i] + problem.specs[i].n_params)
             for i in idx])
        subs.append((sub, sub_sm, np.asarray(idx), take,
                     float(fp.capacities[b])))
    return subs


STAGES = ("telemetry", "tick", "fit", "solve", "solve_many", "decide",
          "baselines")

FIT_S_LIST = (3, 9, 27, 96)   # fit_phase sweep (96: the ISSUE 8 gate point)
FIT_WINDOW = 256              # steady-state window rows per relation
                              # (long-running deployment, capped by
                              # the agent's table retention)


def fit_phase_bench(s_list=None, reps=None):
    """Fit-phase transfer breakdown, batch vs streaming (ISSUE 8).

    Per |S| (one paper-shaped relation per service: 3 features, degree 2,
    a ``FIT_WINDOW``-row training window in a ``TrainingTable``), the full
    steady-state fit phase as the agent runs it:

    * batch   — the pre-PR path: ``export`` the whole finite-filtered
      design window out of the table, ``pack`` it into the padded host
      buffer, ``upload`` it, run the compiled window fit.
    * stream  — the device-resident Gram engine: ``export`` only the rows
      past the cursor (one, in steady state), ``pack`` the one-row delta,
      ``upload`` it, run the compiled rank-1 push + solve-from-Gram.

    ``*_fit_us`` are the end-to-end phase times (export+pack+upload+update
    in one call, result blocked) — the number the regression gate tracks.
    """
    from repro.core.telemetry import TrainingTable

    s_list = s_list if s_list is not None else FIT_S_LIST
    reps = reps if reps is not None else REPS
    rng = np.random.default_rng(0)
    feats, target = ("cores", "quality", "rps"), "tp_max"
    out = {}
    for s_count in s_list:
        plan = BatchedFitPlan(
            [dict(n_features=3, degree=2, x_scale=[8.0, 1000.0, 100.0])
             for _ in range(s_count)],
            row_capacity=pad_capacity(FIT_WINDOW), ridge=1e-4)
        table = TrainingTable(retention=pad_capacity(FIT_WINDOW))
        sids = [f"s{i}" for i in range(s_count)]
        for sid in sids:
            for _ in range(FIT_WINDOW):
                c, q, r = (float(rng.uniform(0.1, 8.0)),
                           float(rng.uniform(100, 1000)),
                           float(rng.uniform(1, 100)))
                table.append(sid, {"cores": c, "quality": q, "rps": r,
                                   target: 20 * c - q / 100.0
                                   + float(rng.normal(0, 0.1))})
        cursors = [table.appended(sid) - 1 for sid in sids]

        def export_window():
            return [table.design_matrix(sid, feats, target) for sid in sids]

        def export_delta():
            return [table.delta_matrix(sid, feats, target, cur)[:2]
                    for sid, cur in zip(sids, cursors)]

        data = export_window()
        deltas = export_delta()
        row = {}

        # batch: full-window export + rebuild + upload + compiled fit
        row["batch_export_us"] = _bench(export_window, reps)
        row["batch_pack_us"] = _bench(lambda: plan.fill_packed(data), reps)
        buf = plan.fill_packed(data)
        row["batch_upload_us"] = _bench(
            lambda: jax.device_put(buf).block_until_ready(), reps)
        dev = jax.device_put(buf)
        batch_fit = jax.jit(lambda b: regression.fit_batched_arrays(
            *plan.unpack(b), plan._E, plan._tmask, plan._nterms,
            plan._scale, plan.ridge, plan.max_degree))
        row["batch_update_us"] = _bench(
            lambda: batch_fit(dev).block_until_ready(), reps)
        row["batch_fit_us"] = _bench(
            lambda: batch_fit(jax.device_put(
                plan.fill_packed(export_window()))).block_until_ready(),
            reps)

        # stream: one-row delta export + pack + upload + push-and-solve
        state = plan.stream_rebuild(data)
        row["stream_export_us"] = _bench(export_delta, reps)
        row["stream_pack_us"] = _bench(lambda: plan.fill_delta(deltas, 1),
                                       reps)
        dbuf = plan.fill_delta(deltas, 1)
        row["stream_upload_us"] = _bench(
            lambda: jax.device_put(dbuf).block_until_ready(), reps)
        ddev = jax.device_put(dbuf)
        stream_fit = jax.jit(lambda st, b: plan.stream_fit_arrays(
            plan.stream_update_arrays(st, *plan.unpack_delta(b, 1))))
        row["stream_update_us"] = _bench(
            lambda: stream_fit(state, ddev).block_until_ready(), reps)
        row["stream_fit_us"] = _bench(
            lambda: stream_fit(state, jax.device_put(
                plan.fill_delta(export_delta(), 1))).block_until_ready(),
            reps)

        row["stream_speedup"] = row["batch_fit_us"] / row["stream_fit_us"]
        # bytes moved host->device per steady-state cycle
        row["batch_upload_bytes"] = int(buf.nbytes)
        row["stream_upload_bytes"] = int(dbuf.nbytes)
        out[f"S={s_count}"] = row
    return out


def run(s_list=None, reps=None, solve_reps=None, stages=None):
    """``stages``: subset of STAGES to measure (None = all).  The --check
    gate passes ("decide",) so CI only trains the default agent and skips
    the slow slsqp/seed-loop/fleet baselines it would discard anyway."""
    s_list = s_list if s_list is not None else S_LIST
    reps = reps if reps is not None else REPS
    solve_reps = solve_reps if solve_reps is not None else SOLVE_REPS
    has = (lambda s: True) if stages is None else (lambda s: s in stages)
    results = {}
    for s_count in s_list:
        replicas = max(s_count // 3, 1)
        env, agent = _trained_agent(replicas)                    # default: pgd
        if has("baselines"):
            env_s, agent_s = _trained_agent(replicas, backend="slsqp")
            env_l, agent_l = _trained_agent(replicas, fused=False,
                                            backend="slsqp")    # seed loop
        row = {}

        # telemetry: bulk scrape + bulk windowed aggregation
        t_holder = [env.t]

        def scrape():
            t_holder[0] += 1.0
            env.platform.scrape(t_holder[0])

        if has("telemetry"):
            row["telemetry_scrape_us"] = _bench(scrape, reps)
            row["telemetry_window_us"] = _bench(
                lambda: env.platform.window_states(since=t_holder[0] - 5.0,
                                                   until=t_holder[0]), reps)

        # tick: one vectorized step of every container
        if has("tick"):
            row["tick_us"] = _bench(lambda: env.pool.tick(t_holder[0]), reps)

        # fit: batched vs per-relation loop (same table sizes)
        if has("fit"):
            row["fit_us"] = _bench(agent._fit_models, reps)
        if has("fit") and has("baselines"):
            row["fit_loop_us"] = _bench(agent_l._fit_models, reps)
            row["fit_speedup"] = row["fit_loop_us"] / row["fit_us"]

        # solve: all backends on the same warm-started problem
        rps = np.asarray([env.services[k].rps for k in agent.services],
                         np.float32)
        x0 = agent._cached_x
        cap = agent.capacity
        if has("solve"):
            row["solve_us"] = _bench(
                lambda: agent.problem.solve_pgd(agent.stacked, rps, x0, cap),
                solve_reps)
            row["solve_pallas_us"] = _bench(
                lambda: agent.problem.solve_pgd(
                    agent.stacked, rps, x0, cap,
                    objective_impl="pallas_interpret"), solve_reps)
            row["solve_slsqp_us"] = _bench(
                lambda: agent.problem.solve_slsqp(agent.stacked, rps, x0,
                                                  cap), solve_reps)
        if has("solve") and has("baselines"):
            x0_l = agent_l._cached_x
            row["solve_loop_us"] = _bench(
                lambda: agent_l.problem.solve_slsqp(agent_l.models, rps,
                                                    x0_l, cap), solve_reps)
            row["solve_speedup"] = row["solve_loop_us"] / row["solve_us"]

        # solve_many: a 3-host fleet in one vmapped dispatch vs a loop
        if has("solve_many"):
            env_f, agent_f = _trained_agent(replicas, hosts=3)
            fp = agent_f.fleet_problem
            rps_f = np.asarray(
                [env_f.services[k].rps for k in agent_f.services], np.float32)
            x0_f = agent_f._cached_x
            sm_f = agent_f.stacked
            row["solve_many_us"] = _bench(
                lambda: fp.solve_many(sm_f, rps_f, x0_f), solve_reps)
            subs = _fleet_sequential(agent_f)

            def seq():
                for sub, sub_sm, idx, take, sub_cap in subs:
                    sub.solve_pgd(sub_sm, rps_f[idx], x0_f[take], sub_cap)

            row["solve_seq_us"] = _bench(seq, solve_reps)
            row["solve_many_speedup"] = (row["solve_seq_us"]
                                         / row["solve_many_us"])

        # decide: the full per-cycle agent latency, with recompile AND
        # transfer accounting (h2d_* are runtime transfer counters, not jit
        # traces: delta rows legitimately stream every cycle, but a
        # steady-state design-window upload is a regression)
        if has("decide"):
            obs = agent.observe(env.t)
            traces0 = dict(TRACE_COUNTS)
            row["decide_us"] = _bench(lambda: agent.decide(obs), solve_reps)
            row["recompiles_during_decide"] = {
                k: TRACE_COUNTS[k] - traces0.get(k, 0) for k in TRACE_COUNTS
                if not k.startswith("h2d_")
                and TRACE_COUNTS[k] - traces0.get(k, 0)}
            row["design_uploads_during_decide"] = (
                TRACE_COUNTS["h2d_design_upload"]
                - traces0.get("h2d_design_upload", 0))
            row["delta_rows_during_decide"] = (
                TRACE_COUNTS["h2d_delta_rows"]
                - traces0.get("h2d_delta_rows", 0))
        if has("decide") and has("baselines"):
            obs_s = agent_s.observe(env_s.t)
            obs_l = agent_l.observe(env_l.t)
            row["decide_slsqp_us"] = _bench(lambda: agent_s.decide(obs_s),
                                            solve_reps)
            row["decide_loop_us"] = _bench(lambda: agent_l.decide(obs_l),
                                           solve_reps)
            row["decide_speedup"] = row["decide_loop_us"] / row["decide_us"]
            row["decide_speedup_vs_slsqp"] = (row["decide_slsqp_us"]
                                              / row["decide_us"])
        results[f"S={s_count}"] = row
    if has("fit"):
        results["fit_phase"] = fit_phase_bench(reps=reps)
    common.save(ARTIFACT, results)
    return results


def report(results) -> None:
    fit_phase = results.get("fit_phase") or {}
    for key, row in fit_phase.items():
        print(f"e7[fit-phase,{key}],{row['stream_fit_us']:.0f},"
              f"batch={row['batch_fit_us']:.0f}us"
              f" speedup={row['stream_speedup']:.2f}x"
              f" bytes={row['stream_upload_bytes']}"
              f"/{row['batch_upload_bytes']}"
              f" pack={row['stream_pack_us']:.0f}"
              f" upload={row['stream_upload_us']:.0f}"
              f" update={row['stream_update_us']:.0f}us")
    for key, row in results.items():
        if key == "fit_phase":
            continue
        for stage in ("telemetry_scrape", "telemetry_window", "tick"):
            print(f"e7[{stage},{key}],{row[stage + '_us']:.0f},")
        for stage in ("fit", "solve", "decide"):
            print(f"e7[{stage},{key}],{row[stage + '_us']:.0f},"
                  f"speedup={row[stage + '_speedup']:.2f}x"
                  f" loop={row[stage + '_loop_us']:.0f}us")
        print(f"e7[solve-backends,{key}],{row['solve_us']:.0f},"
              f"pallas={row.get('solve_pallas_us', 0):.0f}us"
              f" slsqp={row.get('solve_slsqp_us', 0):.0f}us")
        if "solve_many_us" in row:
            print(f"e7[solve-many,{key}],{row['solve_many_us']:.0f},"
                  f"seq={row['solve_seq_us']:.0f}us"
                  f" speedup={row['solve_many_speedup']:.2f}x")
        if "decide_slsqp_us" in row:
            print(f"e7[decide-vs-slsqp,{key}],{row['decide_us']:.0f},"
                  f"slsqp={row['decide_slsqp_us']:.0f}us"
                  f" speedup={row['decide_speedup_vs_slsqp']:.2f}x")
        rec = row.get("recompiles_during_decide") or {}
        print(f"e7[recompiles,{key}],0,{sum(rec.values())}")
        if "design_uploads_during_decide" in row:
            print(f"e7[steady-uploads,{key}],0,"
                  f"{row['design_uploads_during_decide']}"
                  f" delta_rows={row['delta_rows_during_decide']}")


def main():
    report(run())


if __name__ == "__main__":
    main()
