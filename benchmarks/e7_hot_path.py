"""E7 (beyond-paper): per-stage cycle hot-path latency vs |S|.

The paper's E6 blames per-cycle agent runtime — "poor parallelization of the
numerical solver" — for the ceiling on services per device.  This benchmark
instruments every stage of the fused batched cycle engine at |S| in
{3, 9, 27} (replicated QR/CV/PC on one device, proportional capacity):

* ``telemetry`` — one full scrape (all containers, bulk ring write) and one
  bulk ``window_states`` aggregation;
* ``tick``      — one vectorized ``ContainerPool.tick`` of the whole fleet;
* ``fit``       — the batched stacked ridge fit vs the seed's per-relation
  ``fit_polynomial`` loop;
* ``solve``     — SLSQP on the fused gather+segment_sum objective vs the
  seed's per-service loop objective;
* ``decide``    — the full RASK fit+solve decision, fused vs loop
  (``RaskConfig(fused=False)``), i.e. the per-cycle agent latency E4-E6 plot.

All timings are steady-state (post jit warm-up) medians.  The artifact also
records jit trace counts over the timed window — zero recompiles after the
first cycle at fixed padding is an acceptance gate of the fused engine.
"""
import time

import numpy as np

from repro.core.regression import TRACE_COUNTS

from . import common

S_LIST = (3, 9, 27)
REPS = 20            # reps for cheap stages (telemetry / tick / fit)
SOLVE_REPS = 5       # reps for solve / decide (SLSQP-bound)
TRAIN_CYCLES = 30    # exploration cycles populating the training table
# quick/CI runs save under a different name so the committed full-sweep
# acceptance artifact is never clobbered by |S|=3 smoke data
ARTIFACT = "e7_hot_path"


def _bench(fn, reps: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)     # us per call


def _trained_agent(replicas: int, fused: bool, seed: int = 0):
    """Environment + RASK agent with a populated training table, one solve
    cycle already done (jit warm)."""
    env = common.make_env(seed=seed, replicas=replicas,
                          capacity=8.0 * replicas)
    agent = common.make_rask(env, seed=seed, xi=TRAIN_CYCLES, eta=0.0,
                             fused=fused)
    # TRAIN_CYCLES exploration cycles + 2 solve cycles (compile + steady)
    env.run(agent, duration_s=(TRAIN_CYCLES + 2) * common.CYCLE_S)
    return env, agent


def run(s_list=None, reps=None, solve_reps=None):
    s_list = s_list if s_list is not None else S_LIST
    reps = reps if reps is not None else REPS
    solve_reps = solve_reps if solve_reps is not None else SOLVE_REPS
    results = {}
    for s_count in s_list:
        replicas = max(s_count // 3, 1)
        env, agent = _trained_agent(replicas, fused=True)
        env_l, agent_l = _trained_agent(replicas, fused=False)
        row = {}

        # telemetry: bulk scrape + bulk windowed aggregation
        t_holder = [env.t]

        def scrape():
            t_holder[0] += 1.0
            env.platform.scrape(t_holder[0])

        row["telemetry_scrape_us"] = _bench(scrape, reps)
        row["telemetry_window_us"] = _bench(
            lambda: env.platform.window_states(since=t_holder[0] - 5.0,
                                               until=t_holder[0]), reps)

        # tick: one vectorized step of every container
        row["tick_us"] = _bench(lambda: env.pool.tick(t_holder[0]), reps)

        # fit: batched vs per-relation loop (same table sizes)
        row["fit_us"] = _bench(agent._fit_models, reps)
        row["fit_loop_us"] = _bench(agent_l._fit_models, reps)

        # solve: fused vs loop objective, warm start from the cached optimum
        rps = np.asarray([env.services[k].rps for k in agent.services],
                         np.float32)
        x0 = agent._cached_x
        x0_l = agent_l._cached_x
        row["solve_us"] = _bench(
            lambda: agent.problem.solve_slsqp(agent.stacked, rps, x0,
                                              agent.capacity), solve_reps)
        row["solve_loop_us"] = _bench(
            lambda: agent_l.problem.solve_slsqp(agent_l.models, rps, x0_l,
                                                agent_l.capacity), solve_reps)

        # decide: the full per-cycle agent latency, with recompile accounting
        obs = agent.observe(env.t)
        obs_l = agent_l.observe(env_l.t)
        traces0 = dict(TRACE_COUNTS)
        row["decide_us"] = _bench(lambda: agent.decide(obs), solve_reps)
        row["recompiles_during_decide"] = {
            k: TRACE_COUNTS[k] - traces0.get(k, 0) for k in TRACE_COUNTS
            if TRACE_COUNTS[k] - traces0.get(k, 0)}
        row["decide_loop_us"] = _bench(lambda: agent_l.decide(obs_l),
                                       solve_reps)
        row["decide_speedup"] = row["decide_loop_us"] / row["decide_us"]
        row["fit_speedup"] = row["fit_loop_us"] / row["fit_us"]
        row["solve_speedup"] = row["solve_loop_us"] / row["solve_us"]
        results[f"S={s_count}"] = row
    common.save(ARTIFACT, results)
    return results


def report(results) -> None:
    for key, row in results.items():
        for stage in ("telemetry_scrape", "telemetry_window", "tick"):
            print(f"e7[{stage},{key}],{row[stage + '_us']:.0f},")
        for stage in ("fit", "solve", "decide"):
            print(f"e7[{stage},{key}],{row[stage + '_us']:.0f},"
                  f"speedup={row[stage + '_speedup']:.2f}x"
                  f" loop={row[stage + '_loop_us']:.0f}us")
        rec = row.get("recompiles_during_decide") or {}
        print(f"e7[recompiles,{key}],0,{sum(rec.values())}")


def main():
    report(run())


if __name__ == "__main__":
    main()
