"""E4 (Fig. 9): RASK runtime + fulfillment vs number of elasticity
dimensions (1 = cores only; 2 = +data quality; 3 = +model size for CV).

Dimensionality is restricted by *freezing* the extra parameters at their
defaults inside the solver bounds (lower == upper), so the optimization
problem genuinely shrinks, as in the paper.
"""
import numpy as np

from repro.core.rask import RASKAgent, RaskConfig
from repro.core.solver import ServiceSpec, SolverProblem

from . import common


def restrict_dimensions(agent: RASKAgent, dims: int) -> None:
    """Freeze parameters beyond ``dims`` by collapsing their bounds."""
    keep_by_dim = {1: ("cores",),
                   2: ("cores", "data_quality"),
                   3: ("cores", "data_quality", "model_size")}
    keep = keep_by_dim[dims]
    specs = []
    for spec in agent.problem.specs:
        svc = agent.platform.service(spec.name)
        lower, upper = list(spec.lower), list(spec.upper)
        for i, pname in enumerate(spec.param_names):
            if pname not in keep:
                d = svc.api.parameter(pname).default
                lower[i] = upper[i] = d
        specs.append(ServiceSpec(spec.name, spec.param_names, tuple(lower),
                                 tuple(upper), spec.resource_mask, spec.slos,
                                 spec.relation_features))
    agent.problem = SolverProblem(specs)


def run(reps: int = common.REPS, duration: float = common.E3_DURATION / 2,
        cache: bool = True, backend: str = "slsqp"):
    results = {}
    for dims in (1, 2, 3):
        runs = []
        for rep in range(reps):
            patterns = common.e3_patterns("diurnal", duration, seed=rep)
            env = common.make_env(seed=rep, patterns=patterns)
            agent = common.make_rask(env, seed=rep, xi=20, eta=0.0,
                                     cache=cache, backend=backend)
            restrict_dimensions(agent, dims)
            runs.append(common.run_agent(env, agent, duration))
        results[dims] = {
            "median_runtime_ms": float(np.median(
                np.concatenate([r["runtime_ms"] for r in runs]))),
            "runtime_ms_p95": float(np.percentile(
                np.concatenate([r["runtime_ms"] for r in runs]), 95)),
            "median_fulfillment": float(np.median(
                np.concatenate([r["fulfillment"] for r in runs]))),
        }
    common.save(f"e4_dimensions_{backend}_cache{int(cache)}", results)
    return results


def main():
    for backend in ("slsqp", "pgd"):
        r = run(backend=backend)
        for dims, v in r.items():
            print(f"e4[{backend},dims={dims}],"
                  f"{v['median_runtime_ms'] * 1e3:.0f},"
                  f"{v['median_fulfillment']:.4f}")


if __name__ == "__main__":
    main()
