"""E3 (Fig. 8): RASK vs VPA vs DQN under Bursty/Diurnal request patterns.

Agents first experience the default environment for RASK's 20-cycle
exploration (like the paper, where agents are trained before E3 and then
face unseen patterns). Derived headline: relative SLO-violation reduction
of RASK vs the best baseline during high load (the paper reports 28%).
"""
import numpy as np

from repro.core.agents import DQNAgent, DQNConfig, VPAAgent

from . import common


def _trained_rask(seed, pattern_env_seed=0):
    """Train RASK on the default constant-RPS env (E1 conditions)."""
    env = common.make_env(seed=seed)
    agent = common.make_rask(env, seed=seed, xi=20, eta=0.0)
    common.run_agent(env, agent, 300.0)
    return agent


def run(reps: int = common.REPS, duration: float = common.E3_DURATION):
    results = {}
    for kind in ("bursty", "diurnal"):
        per_agent = {}
        for name in ("rask", "rask_pgd", "vpa", "dqn"):
            runs = []
            for rep in range(reps):
                patterns = common.e3_patterns(kind, duration, seed=rep)
                env = common.make_env(seed=rep, patterns=patterns)
                if name in ("rask", "rask_pgd"):
                    # trained policy, transplanted to the pattern env
                    trained = _trained_rask(seed=rep)
                    backend = "pgd" if name == "rask_pgd" else "slsqp"
                    agent = common.make_rask(env, seed=rep, xi=0, eta=0.0,
                                             backend=backend)
                    agent.table = trained.table
                    agent.rounds = trained.rounds
                    agent._cached_x = trained._cached_x
                elif name == "vpa":
                    agent = VPAAgent(env.platform)
                else:
                    trained = _trained_rask(seed=rep)
                    models = {s: m["tp_max"]
                              for s, m in trained.models.items()}
                    feats = {s: trained.knowledge[
                        env.platform.service(s).sid.type]["tp_max"]
                        for s in trained.services}
                    rps = {s: env.platform.service(s).backend.profile
                           .default_rps for s in trained.services}
                    agent = DQNAgent(env.platform,
                                     DQNConfig(train_steps=1500), seed=rep)
                    agent.pretrain(models, rps, feats)
                runs.append(common.run_agent(env, agent, duration))
            curves = np.asarray([r["fulfillment"] for r in runs])
            loads = np.asarray([r["load"] for r in runs])
            peak = loads >= 0.4                     # paper: "high load"
            viol = {str(t): float(np.mean(curves < t))
                    for t in (0.8, 0.9, 0.95, 1.0)}
            viol_peak = {str(t): float(np.mean(curves[peak] < t))
                         for t in (0.8, 0.9, 0.95, 1.0)}
            per_agent[name] = {
                "mean_curve": curves.mean(0).tolist(),
                "curves": curves.tolist(),
                "mean_fulfillment": float(curves.mean()),
                "peak_fulfillment": float(curves[peak].mean()),
                "low_fulfillment": float(curves[~peak].mean()),
                "violations": viol,
                "violations_peak": viol_peak,
            }
        # headline: violation (fulfillment < 0.9) reduction at high load
        best_base = min(per_agent["vpa"]["violations_peak"]["0.9"],
                        per_agent["dqn"]["violations_peak"]["0.9"])
        rask_v = min(per_agent["rask"]["violations_peak"]["0.9"],
                     per_agent["rask_pgd"]["violations_peak"]["0.9"])
        per_agent["violation_reduction_vs_best_baseline"] = \
            float(1.0 - rask_v / best_base) if best_base > 0 else 0.0
        results[kind] = per_agent
    common.save("e3_sota_comparison", results)
    return results


def main():
    r = run()
    for kind, pa in r.items():
        for agent in ("rask", "vpa", "dqn"):
            print(f"e3[{kind},{agent}],0,{pa[agent]['mean_fulfillment']:.4f}")
        print(f"e3[{kind},reduction],0,"
              f"{pa['violation_reduction_vs_best_baseline']:.4f}")


if __name__ == "__main__":
    main()
