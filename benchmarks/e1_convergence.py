"""E1 (Fig. 5): RASK training convergence vs (xi, eta).

6 hyperparameter combinations x 5 reps x 60 cycles. Derived metric: mean
fulfillment of the last 10 cycles for the paper's chosen config {xi=20,
eta=0} — the paper's claim is that 20 exploration iterations (200 s) are
sufficient.
"""
import numpy as np

from . import common


def run(reps: int = common.REPS, duration: float = common.E1_DURATION):
    combos = [(xi, eta) for xi in (0, 10, 20) for eta in (0.0, 0.1)]
    results = {}
    for xi, eta in combos:
        curves = []
        for rep in range(reps):
            env = common.make_env(seed=rep)
            agent = common.make_rask(env, seed=rep, xi=xi, eta=eta)
            out = common.run_agent(env, agent, duration)
            curves.append(out["fulfillment"])
        arr = np.asarray(curves)
        results[f"xi={xi},eta={eta}"] = {
            "mean_curve": arr.mean(0).tolist(),
            "std_curve": arr.std(0).tolist(),
            "final10_mean": float(arr[:, -10:].mean()),
            "final10_std": float(arr[:, -10:].std()),
        }
    common.save("e1_convergence", results)
    return results


def main():
    r = run()
    for k, v in r.items():
        print(f"e1[{k}],0,{v['final10_mean']:.4f}")


if __name__ == "__main__":
    main()
